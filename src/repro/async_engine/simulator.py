"""Event-driven virtual-clock simulator for heterogeneous asynchronous
low-communication training.

This is the reference runtime for every paper experiment: worker paces map
1:1 to the paper's (1, 2, 6, 15)-style configurations, the clock is
simulated seconds (deterministic on CPU), and the actual inner training is
executed for real — only *time* is virtual. Supports:

  - async (HeLoCo / MLA / Nesterov) and sync (Nesterov) modes
  - DyLU straggler mitigation (pace-proportional local steps)
  - fixed / flexible shard-to-worker assignment (App. A.6)
  - pseudo-gradient compression with error feedback
  - fault injection: worker crash (in-flight round lost) + delayed rejoin,
    elastic join/leave
  - periodic checkpointing of server + worker state, restart from latest
"""
from __future__ import annotations

import heapq
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import RunConfig
from repro.core.compression import roundtrip_with_error_feedback
from repro.async_engine.server import Synchronizer
from repro.data.synthetic import ShardSampler, eval_batches, make_language_specs
from repro.models import build_model
from repro.optim.adamw import init_adam
from repro.train.inner import pseudo_gradient, run_inner

PyTree = Any


@dataclass
class WorkerSim:
    wid: int
    pace: float                      # seconds per inner step
    lang: Optional[int]              # shard index (None = IID mixture)
    params: PyTree = None            # in-flight initialization (captured)
    opt: Any = None                  # persistent AdamW state
    ef: PyTree = None                # compression error-feedback buffer
    s_i: int = 0                     # outer step at dispatch
    h_steps: int = 0                 # local steps this round
    inner_step_count: int = 0        # lifetime inner steps (for LR schedule)
    alive: bool = True
    dispatch_time: float = 0.0
    generation: int = 0              # incremented on crash: stale events ignored


@dataclass
class FailureEvent:
    time: float
    wid: int
    restart_delay: float = 60.0      # simulated seconds until rejoin


@dataclass
class ElasticEvent:
    time: float
    action: str                      # "join" | "leave"
    wid: int
    pace: float = 1.0
    lang: Optional[int] = None


@dataclass
class History:
    arrivals: List[Dict] = field(default_factory=list)
    evals: List[Dict] = field(default_factory=list)
    tokens: int = 0
    comm_bytes: int = 0
    final_time: float = 0.0

    def summary(self) -> Dict:
        return {
            "outer_steps": len(self.arrivals),
            "tokens": self.tokens,
            "comm_bytes": self.comm_bytes,
            "final_time": self.final_time,
            "final_eval": self.evals[-1] if self.evals else None,
        }


class AsyncSimulator:
    def __init__(self, run_cfg: RunConfig, *,
                 failures: Optional[List[FailureEvent]] = None,
                 elastic: Optional[List[ElasticEvent]] = None):
        self.cfg = run_cfg
        self.model = build_model(run_cfg.model)
        self.specs = make_language_specs(run_cfg.model.vocab_size,
                                         n_langs=max(run_cfg.n_workers, 2),
                                         seed=run_cfg.seed)
        key = jax.random.PRNGKey(run_cfg.seed)
        init_params = self.model.init(key)
        self.server = Synchronizer(init_params, run_cfg.outer,
                                   run_cfg.n_workers)
        self.workers: Dict[int, WorkerSim] = {}
        for wid in range(run_cfg.n_workers):
            pace = run_cfg.worker_paces[wid % len(run_cfg.worker_paces)]
            lang = (wid % len(self.specs)) if run_cfg.non_iid else None
            self.workers[wid] = WorkerSim(
                wid=wid, pace=pace, lang=lang, opt=init_adam(init_params))
        self.failures = sorted(failures or [], key=lambda f: f.time)
        self.elastic = sorted(elastic or [], key=lambda e: e.time)
        self.lang_tokens = np.zeros(len(self.specs), np.int64)
        self.history = History()
        self.time = 0.0
        self._heap: List[Tuple[float, int, str, int, int]] = []
        self._seq = 0
        self._min_pace = min(w.pace for w in self.workers.values())

    # ------------------------------------------------------------------ utils
    def _push(self, time: float, kind: str, wid: int, gen: int):
        heapq.heappush(self._heap, (time, self._seq, kind, wid, gen))
        self._seq += 1

    def _h_steps(self, w: WorkerSim) -> int:
        if self.cfg.dylu:
            return max(1, int(round(self.cfg.inner_steps *
                                    self._min_pace / w.pace)))
        return self.cfg.inner_steps

    def _pick_lang(self, w: WorkerSim) -> Optional[int]:
        if not self.cfg.non_iid:
            return None
        if self.cfg.shard_assignment == "flexible":
            return int(np.argmin(self.lang_tokens))
        return w.lang

    def _sampler(self, w: WorkerSim, lang: Optional[int]) -> ShardSampler:
        return ShardSampler(self.specs, lang, self.cfg.batch_size,
                            self.cfg.seq_len,
                            seed=self.cfg.seed * 977 + w.wid)

    def _dispatch(self, w: WorkerSim):
        """Capture the worker's initialization and schedule its return."""
        w.params = jax.tree.map(jnp.copy, self.server.worker_init())
        w.s_i = self.server.t
        w.h_steps = self._h_steps(w)
        w.dispatch_time = self.time
        w.cur_lang = self._pick_lang(w)
        duration = w.h_steps * w.pace
        self._push(self.time + duration, "return", w.wid, w.generation)

    # ------------------------------------------------------------ inner round
    def _compute_round(self, w: WorkerSim) -> PyTree:
        lang = getattr(w, "cur_lang", w.lang)
        sampler = self._sampler(w, lang)
        result = run_inner(self.model, self.cfg.inner, w.params, w.opt,
                           sampler, w.h_steps, step_offset=w.inner_step_count)
        w.opt = result.opt
        w.inner_step_count += w.h_steps
        toks = w.h_steps * self.cfg.batch_size * self.cfg.seq_len
        self.history.tokens += toks
        if lang is not None:
            self.lang_tokens[lang] += toks
        delta = pseudo_gradient(w.params, result.params)
        # int8 rides the server's packed layout: per-block scales, O(1)
        # kernel launches, and a packed error-feedback buffer per worker.
        layout = (self.server.layout
                  if self.cfg.outer.compression == "int8" else None)
        decoded, w.ef, nbytes = roundtrip_with_error_feedback(
            delta, w.ef, self.cfg.outer.compression,
            self.cfg.outer.topk_ratio, layout=layout)
        if not self.cfg.outer.error_feedback:
            w.ef = None
        self.history.comm_bytes += nbytes
        return decoded

    # -------------------------------------------------------------- main loop
    def run(self, eval_every: int = 0,
            eval_fn: Optional[Callable[[PyTree, int, float], Dict]] = None,
            ckpt_every: int = 0, ckpt_dir: str = "") -> History:
        if self.cfg.outer.method == "sync_nesterov":
            return self._run_sync(eval_every, eval_fn, ckpt_every, ckpt_dir)
        for w in self.workers.values():
            self._dispatch(w)
        fail_idx = el_idx = 0
        target = self.cfg.outer_steps
        while self.server.t < target and self._heap:
            time, _, kind, wid, gen = heapq.heappop(self._heap)
            # interleave failure / elastic events that occur first
            while (fail_idx < len(self.failures)
                   and self.failures[fail_idx].time <= time):
                self._handle_failure(self.failures[fail_idx])
                fail_idx += 1
            while (el_idx < len(self.elastic)
                   and self.elastic[el_idx].time <= time):
                self._handle_elastic(self.elastic[el_idx])
                el_idx += 1
            self.time = time
            if kind == "restart":
                w = self.workers.get(wid)
                if w is not None:
                    w.alive = True
                    self._dispatch(w)
                continue
            w = self.workers.get(wid)
            if w is None or not w.alive or gen != w.generation:
                continue  # stale event (crashed/removed worker)
            delta = self._compute_round(w)
            rec = self.server.on_arrival(
                delta, w.s_i, w.wid, sim_time=self.time,
                lang=(self.specs[w.cur_lang].lang
                      if getattr(w, "cur_lang", None) is not None else "iid"))
            self.history.arrivals.append(rec.__dict__)
            t = self.server.t
            if eval_every and eval_fn and t % eval_every == 0:
                self.history.evals.append(eval_fn(self.server.state.params,
                                                  t, self.time))
            if ckpt_every and ckpt_dir and t % ckpt_every == 0:
                self.checkpoint(ckpt_dir)
            if self.server.t < target:
                self._dispatch(w)
        self.history.final_time = self.time
        if eval_fn and (not self.history.evals
                        or self.history.evals[-1]["step"] != self.server.t):
            self.history.evals.append(eval_fn(self.server.state.params,
                                              self.server.t, self.time))
        return self.history

    def _run_sync(self, eval_every, eval_fn, ckpt_every, ckpt_dir) -> History:
        target = self.cfg.outer_steps
        while self.server.t < target:
            deltas = []
            round_time = 0.0
            for w in self.workers.values():
                if not w.alive:
                    continue
                w.params = jax.tree.map(jnp.copy, self.server.worker_init())
                w.s_i = self.server.t
                w.h_steps = self._h_steps(w)
                w.cur_lang = self._pick_lang(w)
                deltas.append(self._compute_round(w))
                round_time = max(round_time, w.h_steps * w.pace)
            self.time += round_time  # barrier: slowest worker gates the round
            rec = self.server.on_sync_round(deltas, sim_time=self.time)
            self.history.arrivals.append(rec.__dict__)
            t = self.server.t
            if eval_every and eval_fn and t % eval_every == 0:
                self.history.evals.append(eval_fn(self.server.state.params,
                                                  t, self.time))
            if ckpt_every and ckpt_dir and t % ckpt_every == 0:
                self.checkpoint(ckpt_dir)
        self.history.final_time = self.time
        if eval_fn and (not self.history.evals
                        or self.history.evals[-1]["step"] != self.server.t):
            self.history.evals.append(eval_fn(self.server.state.params,
                                              self.server.t, self.time))
        return self.history

    # ------------------------------------------------------- fault tolerance
    def _handle_failure(self, ev: FailureEvent):
        w = self.workers.get(ev.wid)
        if w is None:
            return
        w.alive = False
        w.generation += 1           # in-flight round is lost
        w.ef = None
        self._push(ev.time + ev.restart_delay, "restart", w.wid, w.generation)

    def _handle_elastic(self, ev: ElasticEvent):
        if ev.action == "join":
            w = WorkerSim(wid=ev.wid, pace=ev.pace, lang=ev.lang,
                          opt=init_adam(self.server.state.params))
            self.workers[ev.wid] = w
            self.server.set_n_workers(
                sum(1 for x in self.workers.values() if x.alive) )
            self._dispatch(w)
        elif ev.action == "leave":
            w = self.workers.pop(ev.wid, None)
            if w is not None:
                w.generation += 1
            self.server.set_n_workers(
                sum(1 for x in self.workers.values() if x.alive))
        self._min_pace = min((x.pace for x in self.workers.values()
                              if x.alive), default=1.0)

    # ---------------------------------------------------------- checkpointing
    def server_tree(self) -> Dict:
        return {"params": self.server.state.params,
                "momentum": self.server.state.momentum,
                "step": self.server.state.step}

    def checkpoint(self, ckpt_dir: str) -> str:
        path = os.path.join(ckpt_dir, f"step_{self.server.t}.npz")
        meta = {"time": self.time, "tokens": int(self.history.tokens)}
        ckpt.save(path, self.server_tree(), meta)
        return path

    def restore(self, path: str):
        tree, meta = ckpt.restore(path, self.server_tree())
        self.server.state = self.server.state._replace(
            params=tree["params"],
            momentum=tree["momentum"],
            step=jnp.asarray(tree["step"]))
        self.time = float(meta.get("time", 0.0))
        self.history.tokens = int(meta.get("tokens", 0))
        # in-flight worker rounds are lost on restart (real-world semantics)
        self._heap.clear()
        for w in self.workers.values():
            w.generation += 1
            if w.alive:
                self._dispatch(w)


def make_eval_fn(sim: AsyncSimulator, batch: int = 16, seq: int = None):
    """Per-language + mean validation loss (Fig. 2/3 protocol)."""
    seq = seq or sim.cfg.seq_len
    batches = eval_batches(sim.specs, batch, seq, seed=sim.cfg.seed + 4242)
    model = sim.model

    @jax.jit
    def loss_of(params, tokens, labels):
        return model.loss(params, {"tokens": tokens, "labels": labels})[0]

    def eval_fn(params, step, time):
        per = {}
        for b in batches:
            per[b["lang"]] = float(loss_of(params, jnp.asarray(b["tokens"]),
                                           jnp.asarray(b["labels"])))
        mean = float(np.mean(list(per.values())))
        return {"step": step, "time": time, "mean": mean, "per_lang": per}

    return eval_fn
