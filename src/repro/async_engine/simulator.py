"""Event-driven virtual-clock simulator for heterogeneous asynchronous
low-communication training.

This is the reference engine for every paper experiment: worker paces map
1:1 to the paper's (1, 2, 6, 15)-style configurations, the clock is
simulated seconds (deterministic on CPU), and the actual inner training is
executed for real — only *time* is virtual. All scheduling semantics
(DyLU, fixed/flexible shard assignment, compression + error feedback,
fault injection, elastic membership, checkpoint/restore) live in the
shared ``EngineBase`` (``repro.async_engine.engine``) so the wall-clock
``ConcurrentRuntime`` inherits them unchanged; the simulator's only
specialization is *lazy* execution — a dispatched round is stored and
computed in-line when its virtual return event pops off the heap.

``ConcurrentRuntime`` in deterministic mode runs this exact event loop
with eager threaded compute, which is why the two engines agree
arrival-for-arrival (see docs/runtime.md).
"""
from __future__ import annotations

from typing import Dict

from repro.async_engine.engine import (          # noqa: F401 (re-exports)
    ElasticEvent, EngineBase, FailureEvent, History, RoundResult, RoundTask,
    Worker, make_engine, make_eval_fn,
)

# Backwards-compatible name: the worker record predates the shared engine.
WorkerSim = Worker


class AsyncSimulator(EngineBase):
    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._pending: Dict[int, RoundTask] = {}

    def _submit(self, task: RoundTask):
        """Lazy execution: park the captured round until its virtual
        return event fires (keyed by the engine-unique task id — a crash
        orphans the entry, which is garbage-collected lazily)."""
        self._pending[task.task_id] = task

    def _obtain(self, w: Worker) -> RoundResult:
        res = self._execute(self._pending.pop(w.pending_task_id))
        if len(self._pending) > len(self.workers):      # orphaned crash tasks
            live = {x.pending_task_id for x in self.workers.values()}
            self._pending = {k: v for k, v in self._pending.items()
                             if k in live}
        return res
