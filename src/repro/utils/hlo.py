"""Parse compiled (SPMD-partitioned) HLO text for collective statistics.

Shapes in `compiled.as_text()` are PER-DEVICE (post-partitioning), so the
byte counts here are per-chip. Wire-byte estimates per collective kind:
  all-reduce        : 2 x result bytes   (ring: reduce-scatter + all-gather)
  all-gather        : result bytes       (each chip receives ~result)
  reduce-scatter    : result bytes x (g-1)  (sends ~operand = result x g)
  all-to-all        : result bytes
  collective-permute: result bytes
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_LINE = re.compile(
    r"=\s*(?P<type>\([^()]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>" + "|".join(_COLL) + r")(?:-start)?\(")

_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _bytes_of_type(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_EXPLICIT.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    return 2


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: count, result_bytes, wire_bytes (per device)."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "result_bytes": 0, "wire_bytes": 0} for k in _COLL}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _LINE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = _bytes_of_type(m.group("type"))
        g = _group_size(line)
        if op == "all-reduce":
            wire = 2 * b * (g - 1) / max(g, 1)
        elif op == "all-gather":
            wire = b * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = b * (g - 1)
        else:
            wire = b
        out[op]["count"] += 1
        out[op]["result_bytes"] += b
        out[op]["wire_bytes"] += wire
    return out


def total_wire_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["wire_bytes"] for v in stats.values())


_NO_TRAFFIC_OPS = ("parameter(", "constant(", "get-tuple-element(",
                   "bitcast(", "tuple(", "after-all(", "partition-id(")

_DEF_LINE = re.compile(r"^\s*(?:ROOT\s+)?%\S+\s*=\s*"
                       r"(\([^()]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+(\S+?)\(")
_COMP_START = re.compile(r"^(%?\S+)\s.*\{\s*(?://.*)?$")


def hbm_traffic_estimate(hlo_text: str) -> float:
    """Approximate post-fusion HBM traffic per device: sum of output bytes of
    instructions OUTSIDE fusion computations (fusion internals live in
    VMEM/registers), counted twice (one write + one read by a consumer);
    entry parameters counted once (read)."""
    total = 0.0
    in_fused = False
    for raw in hlo_text.splitlines():
        stripped = raw.strip()
        # computation headers look like: `%name (args) -> type {`
        if stripped.endswith("{") and "->" in stripped:
            name = stripped.split()[0].lstrip("%")
            in_fused = name.startswith(("fused", "wide.fused"))
            continue
        if stripped.startswith("ENTRY"):
            in_fused = False
            continue
        if in_fused:
            continue
        m = _DEF_LINE.match(raw)
        if not m:
            continue
        op = m.group(2)
        if op in ("get-tuple-element", "bitcast", "tuple", "constant",
                  "after-all", "partition-id"):
            continue
        b = _bytes_of_type(m.group(1))
        total += b if op == "parameter" else 2.0 * b
    return total


def group_size_histogram(hlo_text: str) -> Dict[int, int]:
    """Collective count per replica-group size. A DiLoCo-correct multi-pod
    inner step must show no groups of size 2 (pod pairs) or >= 32 (merged
    pod x data/model axes)."""
    hist: Dict[int, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _LINE.search(line)
        if not m:
            continue
        g = _group_size(line)
        hist[g] = hist.get(g, 0) + 1
    return hist


def has_axis_collectives(hlo_text: str, n_partitions: int,
                         axis_group_size: int) -> bool:
    """Heuristic: any collective whose group size equals axis_group_size."""
    for line in hlo_text.splitlines():
        m = _LINE.search(line)
        if m and _group_size(line) == axis_group_size:
            return True
    return False
