"""Optional-``hypothesis`` shim for the property-based tests.

The test suite uses hypothesis when it is installed. When it is absent
(the pinned CI image does not ship it), this module provides a tiny
deterministic stand-in implementing the small strategy surface the tests
actually use (floats / integers / lists, ``@given``, ``@settings``): each
``@given`` test runs a fixed, seeded set of examples — boundary values
first, then uniform draws — so the suite still exercises the property
tests instead of skipping them wholesale.

Usage in tests:  ``from repro.utils.hypcompat import given, settings, st``
"""
from __future__ import annotations

import random

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _SEED = 0x4E70C0
    _MAX_FALLBACK_EXAMPLES = 25   # cap: deterministic examples, not search

    class _Strategy:
        def __init__(self, sample, boundaries=()):
            self._sample = sample
            self.boundaries = tuple(boundaries)

        def sample(self, rng):
            return self._sample(rng)

    class _Namespace:
        @staticmethod
        def floats(min_value=-1e9, max_value=1e9, allow_nan=True,
                   allow_infinity=None, **_):
            lo, hi = float(min_value), float(max_value)
            bounds = [lo, hi] + ([0.0] if lo <= 0.0 <= hi else [])
            return _Strategy(lambda rng: rng.uniform(lo, hi), bounds)

        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1, **_):
            lo, hi = int(min_value), int(max_value)
            return _Strategy(lambda rng: rng.randint(lo, hi), [lo, hi])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_):
            def gen(rng):
                n = rng.randint(min_size, max_size)
                return [elements.sample(rng) for _ in range(n)]
            # boundary: shortest and longest lists of boundary elements
            bounds = []
            for size in (min_size, max_size):
                for b in elements.boundaries or (0,):
                    bounds.append([b] * size)
            return _Strategy(gen, bounds)

    st = _Namespace()

    def settings(max_examples=None, deadline=None, **_):
        def deco(fn):
            if max_examples is not None:
                fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps — pytest follows __wrapped__ when
            # inspecting signatures and would mistake the property
            # arguments for fixtures; the wrapper must look zero-arg.
            def run():
                # @settings usually sits ABOVE @given, so it annotates
                # this wrapper, not the inner fn — check both.
                requested = getattr(run, "_hyp_max_examples",
                                    getattr(fn, "_hyp_max_examples", 100))
                budget = min(requested, _MAX_FALLBACK_EXAMPLES)
                rng = random.Random(_SEED)
                # boundary examples first (aligned across strategies),
                # then seeded uniform draws up to the budget.
                n_bound = max((len(s.boundaries) for s in strategies),
                              default=0)
                examples = []
                for i in range(n_bound):
                    examples.append(tuple(
                        s.boundaries[i % len(s.boundaries)]
                        if s.boundaries else s.sample(rng)
                        for s in strategies))
                while len(examples) < budget:
                    examples.append(tuple(s.sample(rng) for s in strategies))
                for ex in examples[:budget]:
                    fn(*ex)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco
