"""Fault-tolerant checkpointing: full synchronizer + worker state to npz
with a tree manifest and content hash; atomic writes; optional async save
thread. Restore is bit-exact (tested), which is what makes
checkpoint/restart a real recovery mechanism rather than best-effort.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    # jax.tree.flatten_with_path only exists in newer jax; tree_util's
    # spelling works across the versions this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out


def tree_structure_manifest(tree: PyTree) -> str:
    return str(jax.tree.structure(tree))


def save(path: str, tree: PyTree, meta: Optional[Dict] = None) -> str:
    """Atomic save; returns the content hash."""
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(flat[k].tobytes())
    digest = h.hexdigest()
    manifest = {
        "hash": digest,
        "structure": tree_structure_manifest(tree),
        "meta": meta or {},
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    mtmp = path + ".manifest.tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, path + ".manifest.json")
    return digest


def restore(path: str, like: PyTree, verify: bool = True) -> Tuple[PyTree, Dict]:
    """Restore into the structure of `like`. Verifies the content hash."""
    with open(path + ".manifest.json") as f:
        manifest = json.load(f)
    data = np.load(path)
    flat = {k: data[k] for k in data.files}
    if verify:
        h = hashlib.sha256()
        for k in sorted(flat):
            h.update(k.encode())
            h.update(flat[k].tobytes())
        if h.hexdigest() != manifest["hash"]:
            raise IOError(f"checkpoint {path} corrupt: hash mismatch")
    ref_flat = _flatten(like)
    missing = set(ref_flat) - set(flat)
    if missing:
        raise IOError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path_) for path_, _ in leaves_ref]
    leaves = [flat[k] for k in keys]
    tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
    return tree, manifest.get("meta", {})


def latest(ckpt_dir: str, prefix: str = "step_") -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    cands = [f for f in os.listdir(ckpt_dir)
             if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    cands.sort(key=lambda f: int(f[len(prefix):-len(".npz")]))
    return os.path.join(ckpt_dir, cands[-1])


class AsyncSaver:
    """Fire-and-forget background saver (single in-flight save; the training
    loop never blocks on I/O)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def submit(self, path: str, tree: PyTree, meta: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        self._thread = threading.Thread(
            target=save, args=(path, host_tree, meta), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
