"""HeLoCo: momentum-guided look-ahead initialization + per-tensor-block
directional correction of stale pseudo-gradients (paper Sections 3, Alg. 1-2).

Everything here is pure JAX and jittable. A "block" is a leaf tensor of the
parameter pytree — exactly the paper's granularity ("each block is an
individual model tensor"). For scanned layer stacks (leaves carrying a
leading layer axis) the correction is vmapped over that axis so granularity
matches the unstacked model; pass ``stacked_axes`` describing how many
leading axes of each leaf are layer axes.

Two arrival implementations share the same math (verified equivalent in
tests/test_packed.py):

  apply_arrival         per-leaf pytree path — the correctness reference
  apply_arrival_packed  fast path over the packed (R, 128) buffer from
                        ``repro.core.packing``: one stats sweep + one fused
                        correct+outer sweep, O(1) kernel launches per
                        arrival (see docs/packed_layout.md)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig

PyTree = Any


class OuterState(NamedTuple):
    """Synchronizer state: outer params + Nesterov momentum buffer.

    ``aux`` is per-method auxiliary state (``None`` for the standard
    Nesterov schedule; a gradient-accumulator pytree for buffered methods
    such as delayed-Nesterov — see ``repro.core.methods``)."""
    params: PyTree
    momentum: PyTree
    step: jnp.ndarray          # outer step t (int32)
    aux: Optional[PyTree] = None


def init_outer_state(params: PyTree, with_aux: bool = False) -> OuterState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    aux = (jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                        params) if with_aux else None)
    return OuterState(params=params, momentum=zeros,
                      step=jnp.zeros((), jnp.int32), aux=aux)


# ---------------------------------------------------------------------------
# Eq. 5: momentum-guided look-ahead worker initialization
# ---------------------------------------------------------------------------

def lookahead_init(state: OuterState, outer_lr: float, mu: float) -> PyTree:
    """theta_bar_r = theta_r - eta_r * mu * m_r  (HeLoCo + MLA worker init)."""
    return jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - outer_lr * mu * m).astype(p.dtype),
        state.params, state.momentum)


# ---------------------------------------------------------------------------
# Eqs. 7-16 / Alg. 2: per-block directional correction
# ---------------------------------------------------------------------------

def correct_block(delta: jnp.ndarray, mom: jnp.ndarray,
                  h: HeLoCoConfig) -> jnp.ndarray:
    """Correct ONE tensor block against its momentum block.

    Flattens the block, computes the cosine c_b and applies:
      c_b >= c_ok           : keep
      c_b <  0              : damp the anti-momentum component   (Eq. 10-11)
      0 <= c_b < c_ok       : norm-preserving rotation to v_hat  (Eq. 12-14)
      degenerate norms      : pass through
    """
    u = delta.astype(jnp.float32).reshape(-1)
    v = mom.astype(jnp.float32).reshape(-1)
    nu = jnp.linalg.norm(u)
    nv = jnp.linalg.norm(v)
    safe_nu = jnp.maximum(nu, h.eps)
    safe_nv = jnp.maximum(nv, h.eps)
    u_hat = u / safe_nu
    v_hat = v / safe_nv
    c = jnp.dot(u_hat, v_hat)                                     # Eq. 8
    conf = nu / (nu + h.kappa * nv + h.eps)                       # Eq. 15

    # anti-aligned branch (Eq. 10-11)
    beta = jnp.minimum(h.k_s * (-c) * conf, h.beta_max)
    anti = u - beta * c * nu * v_hat

    # weakly-aligned branch (Eq. 12-14)
    lam = jnp.minimum(h.k_d * (1.0 - c) * conf, 1.0)
    u_tilde = (1.0 - lam) * u_hat + lam * v_hat
    weak = nu * u_tilde / jnp.maximum(jnp.linalg.norm(u_tilde), h.eps)

    corrected = jnp.where(c >= h.c_ok, u, jnp.where(c < 0.0, anti, weak))
    degenerate = (nu < h.eps) | (nv < h.eps)
    out = jnp.where(degenerate, u, corrected)
    return out.reshape(delta.shape).astype(delta.dtype)


def block_correct(delta: PyTree, momentum: PyTree, h: HeLoCoConfig,
                  stacked_axes: Optional[PyTree] = None,
                  use_kernel: bool = False) -> PyTree:
    """Alg. 2 over the whole pseudo-gradient pytree.

    stacked_axes: optional pytree of ints (same structure) giving the number
    of leading layer axes per leaf (scanned stacks); the correction is
    vmapped over those axes so each layer's tensor is its own block.
    use_kernel: route each block through the fused Pallas kernel path.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        base = functools.partial(kops.heloco_correct_block, h=h)
    else:
        base = functools.partial(correct_block, h=h)

    if stacked_axes is None:
        return jax.tree.map(base, delta, momentum)

    def apply_one(d, m, n_axes):
        fn = base
        for _ in range(int(n_axes)):
            fn = jax.vmap(fn)
        return fn(d, m)

    return jax.tree.map(apply_one, delta, momentum, stacked_axes)


# ---------------------------------------------------------------------------
# Eqs. 17-19: outer update (shared by Nesterov / MLA / HeLoCo)
# ---------------------------------------------------------------------------

def outer_update(state: OuterState, g: PyTree, outer_lr: float,
                 mu: float, rho: jnp.ndarray | float = 1.0) -> OuterState:
    """m_{t+1} = mu m_t + (1-mu) rho G;  theta_{t+1} = theta_t - eta (G' + mu m_{t+1})."""
    def m_upd(m, gi):
        return mu * m + (1.0 - mu) * rho * gi.astype(jnp.float32)

    def p_upd(p, m_new, gi):
        gf = rho * gi.astype(jnp.float32)
        return (p.astype(jnp.float32) - outer_lr * (gf + mu * m_new)).astype(p.dtype)

    momentum = jax.tree.map(m_upd, state.momentum, g)
    params = jax.tree.map(p_upd, state.params, momentum, g)
    return OuterState(params=params, momentum=momentum, step=state.step + 1,
                      aux=state.aux)


# ---------------------------------------------------------------------------
# Method dispatch: what happens when a pseudo-gradient arrives.
# All per-method behaviour lives in the ``repro.core.methods`` registry;
# the drivers below are method-agnostic.
# ---------------------------------------------------------------------------

def mla_correct(delta: PyTree, momentum: PyTree, outer_lr: float,
                mu: float, tau: jnp.ndarray,
                tau_clip: float = 10.0) -> PyTree:
    """Momentum Look-Ahead (Ajanthan et al. 2025): uniform extrapolation of
    the whole pseudo-gradient along the negative momentum direction,
    proportional to staleness: Delta' = Delta + eta * mu * tau_norm * m,
    with tau_norm = min(tau, tau_clip)/tau_clip (the paper's clip lives on
    the method definition in ``repro.core.methods``).

    (The original MLA applies a single uniform momentum-based shift to the
    entire update; per-block geometry is exactly what it lacks.)
    """
    scale = (outer_lr * mu
             * jnp.minimum(tau.astype(jnp.float32), tau_clip) / tau_clip)
    return jax.tree.map(
        lambda d, m: (d.astype(jnp.float32) + scale * m).astype(d.dtype),
        delta, momentum)


def momentum_decay_update(state: OuterState, outer_lr: float, mu: float,
                          method="heloco",
                          rho: jnp.ndarray | float = 1.0,
                          tau: jnp.ndarray | float = 0.0,
                          phase=None) -> OuterState:
    """Outer step for a DROPPED stale arrival (App. A.6). Equivalent to
    ``apply_arrival`` with a zero pseudo-gradient (for every registered
    method, incl. MLA's momentum extrapolation of the zero delta) but
    skips materialising the zero pytree and the O(d) correction entirely.
    """
    from repro.core import methods as _methods
    m = _methods.resolve(method)
    ctx = _methods.ArrivalCtx(outer_lr=outer_lr, mu=mu, rho=rho,
                              tau=jnp.asarray(tau, jnp.float32), phase=phase)
    if m.custom_update:
        return _methods.scheduled_decay_update(m, ctx, state)
    c_m, c_p = _methods.decay_coeffs(m, ctx)
    momentum = jax.tree.map(lambda mm: c_m * mm, state.momentum)
    params = jax.tree.map(
        lambda p, mm: (p.astype(jnp.float32) - outer_lr * c_p * mm
                       ).astype(p.dtype),
        state.params, state.momentum)
    return OuterState(params=params, momentum=momentum, step=state.step + 1,
                      aux=state.aux)


def apply_arrival(state: OuterState, delta: PyTree, *, method,
                  outer_lr: float, mu: float, h: HeLoCoConfig,
                  rho: jnp.ndarray | float = 1.0,
                  tau: jnp.ndarray | float = 0.0,
                  stacked_axes: Optional[PyTree] = None,
                  use_kernel: bool = False, phase=None) -> OuterState:
    """Process one arriving pseudo-gradient through the chosen method.

    method: any registered ``repro.core.methods`` name/alias or an
    ``OuterMethod`` instance (for sync methods, `delta` is already the
    worker-averaged pseudo-gradient). ``phase`` is the outer-step index at
    arrival — only buffered schedules (delayed-Nesterov) read it.
    """
    from repro.core import methods as _methods
    m = _methods.resolve(method)
    tau = jnp.asarray(tau)
    ctx = _methods.ArrivalCtx(outer_lr=outer_lr, mu=mu, h=h, rho=rho,
                              tau=tau, phase=phase,
                              stacked_axes=stacked_axes,
                              use_kernel=use_kernel)
    g = m.correct(m, ctx, delta, state.momentum)
    if m.custom_update:
        return _methods.scheduled_outer_update(m, ctx, state, g)
    return outer_update(state, g, outer_lr, mu, rho=rho)


def apply_arrivals(state: OuterState, deltas, *, method, outer_lr: float,
                   mu: float, h: HeLoCoConfig, rhos=None, taus=None,
                   phases=None, stacked_axes: Optional[PyTree] = None,
                   use_kernel: bool = False) -> OuterState:
    """Per-leaf REFERENCE of a batched flush: K sequential
    ``apply_arrival`` steps with per-delta rho/tau/phase. This is the
    semantics ``apply_arrivals_packed`` must reproduce (fp32-close; the
    property tests in tests/test_scale.py pin it for every method)."""
    k = len(deltas)
    rhos = [1.0] * k if rhos is None else list(rhos)
    taus = [0.0] * k if taus is None else list(taus)
    phases = [None] * k if phases is None else list(phases)
    for delta, rho, tau, phase in zip(deltas, rhos, taus, phases):
        state = apply_arrival(state, delta, method=method, outer_lr=outer_lr,
                              mu=mu, h=h, rho=rho, tau=tau, phase=phase,
                              stacked_axes=stacked_axes,
                              use_kernel=use_kernel)
    return state


# ---------------------------------------------------------------------------
# Packed fast path: same math, one flat buffer, O(1) kernel launches
# ---------------------------------------------------------------------------

def apply_arrival_packed(pbuf: jnp.ndarray, mbuf: jnp.ndarray,
                         delta: PyTree, layout, *, method,
                         outer_lr: float, mu: float, h: HeLoCoConfig,
                         rho: jnp.ndarray | float = 1.0,
                         tau: jnp.ndarray | float = 0.0,
                         abuf: jnp.ndarray | None = None, phase=None,
                         interpret: bool | None = None,
                         with_stats: bool = False):
    """Process one arrival on the packed (R, 128) outer state.

    pbuf/mbuf: packed fp32 params / momentum (see ``repro.core.packing``);
    abuf: the method's packed auxiliary buffer (buffered methods only).
    delta: the arriving pseudo-gradient pytree (packed here — one fused
    XLA gather/concat, no kernel launches). Returns (pbuf', mbuf') or
    (pbuf', mbuf', abuf') for buffered methods.

    with_stats: additionally return the (R, 4) per-row telemetry moments
    ``[d.m, d.d, m.m, |g_unweighted - d|^2]`` as the LAST element — they
    are an extra output of the same fused sweep, so the launch count and
    the update bytes are unchanged (see ``repro.telemetry``).

    Numerically equivalent to ``apply_arrival`` on fp32 pytrees: every
    registered method reduces to per-block scalars (cu, cv, cq) with
    g = cu*delta + cv*m + cq*delta^2*m (see ``repro.core.methods``), so
    the whole arrival is at most ONE statistics sweep (methods that need
    segment stats, e.g. HeLoCo) plus ONE fused correct+outer sweep —
    <= 2 pallas_calls regardless of #leaves, vs 2 per leaf + a second
    full tree sweep on the per-leaf path.
    """
    from repro.core import methods as _methods
    from repro.core import packing
    from repro.kernels import packed as pk
    from repro.kernels.ops import _auto_interpret

    m = _methods.resolve(method)
    interpret = _auto_interpret(interpret)
    tau = jnp.asarray(tau)
    row_block = jnp.asarray(layout.row_block)
    dbuf = packing.pack(layout, delta)
    ctx = _methods.ArrivalCtx(outer_lr=outer_lr, mu=mu, h=h, rho=rho,
                              tau=tau, phase=phase, layout=layout,
                              interpret=interpret)
    cu, cv, cq = m.packed_coeffs(m, ctx, dbuf, mbuf)
    cu_rows = cu[row_block][:, None]
    cv_rows = cv[row_block][:, None]
    if m.custom_update:          # same dispatch as the per-leaf driver
        if cq is not None:
            raise NotImplementedError(
                f"method {m.name!r}: a quadratic (cq) term combined with "
                "a custom schedule is not supported on the packed path")
        am, bm, ab, cg, cm, ca = _methods.schedule_coeffs(m, ctx)
        if abuf is None:
            abuf = packing.zeros(layout)
        out = pk.packed_correct_outer_acc(
            pbuf, mbuf, abuf, dbuf, cu_rows, cv_rows, outer_lr, rho,
            am, bm, ab, cg, cm, ca, interpret=interpret,
            with_stats=with_stats)
        if m.uses_buffer:
            return out
        return (out[0], out[1], out[3]) if with_stats else out[:2]
    if cq is not None:
        cq_rows = cq[row_block][:, None]
        return pk.packed_correct_outer_quad(
            pbuf, mbuf, dbuf, cu_rows, cv_rows, cq_rows, outer_lr, mu,
            rho, interpret=interpret, with_stats=with_stats)
    return pk.packed_correct_outer(pbuf, mbuf, dbuf, cu_rows, cv_rows,
                                   outer_lr, mu, rho, interpret=interpret,
                                   with_stats=with_stats)


def apply_arrivals_packed(pbuf: jnp.ndarray, mbuf: jnp.ndarray,
                          deltas, layout, *, method,
                          outer_lr: float, mu: float, h: HeLoCoConfig,
                          rhos, taus, abuf: jnp.ndarray | None = None,
                          phases=None, interpret: bool | None = None,
                          with_stats: bool = False):
    """Process K coalesced arrivals on the packed outer state in at most
    TWO Pallas launches total (one optional multi-Gram statistics sweep +
    one K-unrolled fused sweep), vs up to 2K for the sequential path.

    deltas: sequence of K pseudo-gradient pytrees in commit order; rhos /
    taus: per-delta scalars (sequence of K); phases: per-delta outer-step
    indices (buffered schedules only). Semantics are those of K sequential
    ``apply_arrival_packed`` calls with the momentum evolving between
    them — byte-identical modulo fp32 instruction scheduling (the K
    applications chain through registers instead of HBM). K = 1 callers
    should use ``apply_arrival_packed`` directly, which is bitwise
    byte-identical to the pre-batching path.

    with_stats: additionally return (K, R, 4) per-row telemetry moments,
    slice j computed against the momentum as of application j — same
    launch, same count.
    """
    from repro.core import methods as _methods
    from repro.core import packing
    from repro.kernels import packed as pk
    from repro.kernels.ops import _auto_interpret

    m = _methods.resolve(method)
    interpret = _auto_interpret(interpret)
    k = len(deltas)
    row_block = jnp.asarray(layout.row_block)
    dstack = jnp.stack([packing.pack(layout, d) for d in deltas])
    phases = [None] * k if phases is None else list(phases)
    ctxs = [_methods.ArrivalCtx(outer_lr=outer_lr, mu=mu, h=h, rho=rho,
                                tau=jnp.asarray(tau, jnp.float32),
                                phase=phase, layout=layout,
                                interpret=interpret)
            for rho, tau, phase in zip(rhos, taus, phases)]
    cu, cv, cq = _methods.multi_packed_coeffs(m, ctxs, dstack, mbuf)
    cu_rows = cu[:, row_block][:, :, None]
    cv_rows = cv[:, row_block][:, :, None]
    rho_vec = jnp.stack([jnp.asarray(r, jnp.float32) for r in rhos])
    if m.custom_update:
        if cq is not None:
            raise NotImplementedError(
                f"method {m.name!r}: a quadratic (cq) term combined with "
                "a custom schedule is not supported on the packed path")
        am, bm, ab, cg, cm, ca = _methods.multi_schedule_coeffs(m, ctxs)
        if abuf is None:
            abuf = packing.zeros(layout)
        out = pk.packed_multi_correct_outer_acc(
            pbuf, mbuf, abuf, dstack, cu_rows, cv_rows, outer_lr, rho_vec,
            am, bm, ab, cg, cm, ca, interpret=interpret,
            with_stats=with_stats)
        if m.uses_buffer:
            return out
        return (out[0], out[1], out[3]) if with_stats else out[:2]
    if cq is not None:
        cq_rows = cq[:, row_block][:, :, None]
        return pk.packed_multi_correct_outer_quad(
            pbuf, mbuf, dstack, cu_rows, cv_rows, cq_rows, outer_lr, mu,
            rho_vec, interpret=interpret, with_stats=with_stats)
    return pk.packed_multi_correct_outer(
        pbuf, mbuf, dstack, cu_rows, cv_rows, outer_lr, mu, rho_vec,
        interpret=interpret, with_stats=with_stats)


def momentum_decay_packed(pbuf: jnp.ndarray, mbuf: jnp.ndarray,
                          outer_lr: float, mu: float,
                          method="heloco",
                          rho: jnp.ndarray | float = 1.0,
                          tau: jnp.ndarray | float = 0.0,
                          abuf: jnp.ndarray | None = None, phase=None):
    """Dropped-arrival step on packed state (see ``methods.decay_coeffs``).
    Pure elementwise buffer math (XLA fuses it into one pass)."""
    from repro.core import methods as _methods
    m = _methods.resolve(method)
    ctx = _methods.ArrivalCtx(outer_lr=outer_lr, mu=mu, rho=rho,
                              tau=jnp.asarray(tau, jnp.float32), phase=phase)
    if m.custom_update:
        return _methods.scheduled_decay_packed(m, ctx, pbuf, mbuf, abuf)
    c_m, c_p = _methods.decay_coeffs(m, ctx)
    return pbuf - outer_lr * c_p * mbuf, c_m * mbuf
