"""Pseudo-gradient compression with error feedback (beyond-paper,
DiLoCoX-style). Applied on the worker before shipping Delta to the
synchronizer; the error-feedback buffer keeps compression unbiased over
time. Cuts the pod-axis collective bytes by 4x (int8) or ~10x (top-k).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Compressed(NamedTuple):
    payload: PyTree           # int8 values / (values, indices)
    scale: PyTree             # per-tensor scales (fp32)
    kind: str


def _int8_one(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _int8_decode(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _topk_one(x: jnp.ndarray, ratio: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def compress(delta: PyTree, kind: str, topk_ratio: float = 0.1) -> Compressed:
    if kind == "int8":
        qs = jax.tree.map(_int8_one, delta)
        payload = jax.tree.map(lambda t: t[0], qs,
                               is_leaf=lambda t: isinstance(t, tuple))
        scale = jax.tree.map(lambda t: t[1], qs,
                             is_leaf=lambda t: isinstance(t, tuple))
        return Compressed(payload, scale, "int8")
    if kind == "topk":
        qs = jax.tree.map(lambda x: _topk_one(x, topk_ratio), delta)
        return Compressed(
            jax.tree.map(lambda t: (t[0], t[1]), qs,
                         is_leaf=lambda t: isinstance(t, tuple)),
            jax.tree.map(lambda x: jnp.asarray(x.shape, jnp.int32), delta),
            "topk")
    raise ValueError(kind)


def decompress(c: Compressed, like: PyTree) -> PyTree:
    if c.kind == "int8":
        return jax.tree.map(_int8_decode, c.payload, c.scale)
    if c.kind == "topk":
        def dec(pair, ref):
            vals, idx = pair
            flat = jnp.zeros(ref.size, jnp.float32).at[idx].set(vals)
            return flat.reshape(ref.shape)
        return jax.tree.map(dec, c.payload, like,
                            is_leaf=lambda t: isinstance(t, tuple))
    raise ValueError(c.kind)


def compressed_bytes(c: Compressed) -> int:
    if c.kind == "int8":
        n = sum(x.size for x in jax.tree.leaves(c.payload))
        return n + 4 * len(jax.tree.leaves(c.scale))
    vals = jax.tree.leaves(c.payload)
    return sum(x.size * x.dtype.itemsize for x in vals)


def roundtrip_with_error_feedback(delta: PyTree, ef: Optional[PyTree],
                                  kind: str, topk_ratio: float = 0.1
                                  ) -> Tuple[PyTree, PyTree, int]:
    """Worker-side: compress (delta + ef), return (decoded, new_ef, bytes).

    decoded is what the synchronizer receives after decompression; new_ef
    accumulates what compression lost (error feedback).
    """
    if kind == "none":
        zeros = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), delta)
        nbytes = sum(x.size * 4 for x in jax.tree.leaves(delta))
        return delta, zeros, nbytes
    if ef is None:
        ef = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), delta)
    target = jax.tree.map(lambda d, e: d.astype(jnp.float32) + e, delta, ef)
    comp = compress(target, kind, topk_ratio)
    decoded = decompress(comp, target)
    new_ef = jax.tree.map(lambda t, d: t - d, target, decoded)
    return decoded, new_ef, compressed_bytes(comp)
