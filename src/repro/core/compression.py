"""Pseudo-gradient compression with error feedback (beyond-paper,
DiLoCoX-style). Applied on the worker before shipping Delta to the
synchronizer; the error-feedback buffer keeps compression unbiased over
time. Cuts the pod-axis collective bytes by 4x (int8) or ~10x (top-k).

Two int8 paths:
  * per-leaf (``compress``/``decompress``): one scale per tensor, one
    quantize/dequantize pair per leaf — the original reference path.
  * packed (``packed_int8_roundtrip`` and the ``layout=`` argument of
    ``roundtrip_with_error_feedback``): the pytree is flattened through a
    ``repro.core.packing.BlockLayout`` and quantized per BLOCK (same
    granularity, finer for stacked-layer leaves) with O(1) kernel launches
    per round-trip instead of O(#leaves); the error-feedback buffer also
    lives packed, so the whole worker-side compression step is three flat
    sweeps (absmax, quantize, dequantize) over one (R, 128) buffer.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Compressed(NamedTuple):
    payload: PyTree           # int8 values / (values, indices)
    scale: PyTree             # per-tensor scales (fp32)
    kind: str


def _int8_one(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _int8_decode(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _topk_one(x: jnp.ndarray, ratio: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def compress(delta: PyTree, kind: str, topk_ratio: float = 0.1) -> Compressed:
    if kind == "int8":
        qs = jax.tree.map(_int8_one, delta)
        payload = jax.tree.map(lambda t: t[0], qs,
                               is_leaf=lambda t: isinstance(t, tuple))
        scale = jax.tree.map(lambda t: t[1], qs,
                             is_leaf=lambda t: isinstance(t, tuple))
        return Compressed(payload, scale, "int8")
    if kind == "topk":
        qs = jax.tree.map(lambda x: _topk_one(x, topk_ratio), delta)
        return Compressed(
            jax.tree.map(lambda t: (t[0], t[1]), qs,
                         is_leaf=lambda t: isinstance(t, tuple)),
            jax.tree.map(lambda x: jnp.asarray(x.shape, jnp.int32), delta),
            "topk")
    raise ValueError(kind)


def decompress(c: Compressed, like: PyTree) -> PyTree:
    if c.kind == "int8":
        return jax.tree.map(_int8_decode, c.payload, c.scale)
    if c.kind == "topk":
        def dec(pair, ref):
            vals, idx = pair
            flat = jnp.zeros(ref.size, jnp.float32).at[idx].set(vals)
            return flat.reshape(ref.shape)
        return jax.tree.map(dec, c.payload, like,
                            is_leaf=lambda t: isinstance(t, tuple))
    raise ValueError(c.kind)


def compressed_bytes(c: Compressed) -> int:
    if c.kind == "int8":
        n = sum(x.size for x in jax.tree.leaves(c.payload))
        return n + 4 * len(jax.tree.leaves(c.scale))
    vals = jax.tree.leaves(c.payload)
    return sum(x.size * x.dtype.itemsize for x in vals)


def packed_int8_roundtrip(buf: jnp.ndarray, layout,
                          interpret: bool | None = None
                          ) -> Tuple[jnp.ndarray, int]:
    """Per-block int8 fake-quantization of a packed (R, 128) buffer.

    One absmax sweep + an O(R) segment-max gives per-block scales; one
    quantize and one dequantize sweep complete the round-trip — 3 kernel
    launches total regardless of #blocks. Returns (decoded_buf, wire_bytes)
    where wire_bytes counts only real elements (int8) + one fp32 scale per
    block, matching the per-leaf accounting.
    """
    from repro.kernels import packed as pk
    from repro.kernels.ops import _auto_interpret

    interpret = _auto_interpret(interpret)
    row_block = jnp.asarray(layout.row_block)
    rowabs = pk.packed_rowabs(buf, interpret=interpret)[:, 0]
    # blocks are contiguous row spans: static slices beat a segment max
    blockabs = jnp.stack([rowabs[s:e].max()
                          for s, e in layout.block_row_ranges])
    scale = jnp.maximum(blockabs, 1e-12) / 127.0
    scale_rows = scale[row_block][:, None]
    q = pk.packed_quant(buf, scale_rows, interpret=interpret)
    decoded = pk.packed_dequant(q, scale_rows, interpret=interpret)
    nbytes = int(layout.total_elems) + 4 * layout.n_blocks
    return decoded, nbytes


def roundtrip_with_error_feedback(delta: PyTree, ef: Optional[PyTree],
                                  kind: str, topk_ratio: float = 0.1,
                                  layout=None
                                  ) -> Tuple[PyTree, PyTree, int]:
    """Worker-side: compress (delta + ef), return (decoded, new_ef, bytes).

    decoded is what the synchronizer receives after decompression; new_ef
    accumulates what compression lost (error feedback).

    layout: optional ``repro.core.packing.BlockLayout`` for ``delta``.
    With kind="int8" it routes the round-trip through the packed buffer
    (O(1) kernel launches); ``ef`` is then a packed (R, 128) buffer, not a
    pytree (``None`` still means "no error accumulated yet"), and the
    decoded value is returned as a ``packing.Packed`` buffer so the packed
    synchronizer consumes it without an unpack -> re-pack detour.
    """
    if kind == "int8" and layout is not None:
        from repro.core import packing

        dbuf = packing.pack(layout, delta)
        target = dbuf if ef is None else dbuf + ef
        decoded_buf, nbytes = packed_int8_roundtrip(target, layout)
        new_ef = target - decoded_buf
        return packing.Packed(decoded_buf), new_ef, nbytes
    if kind == "none":
        zeros = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), delta)
        nbytes = sum(x.size * 4 for x in jax.tree.leaves(delta))
        return delta, zeros, nbytes
    if ef is None:
        ef = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), delta)
    target = jax.tree.map(lambda d, e: d.astype(jnp.float32) + e, delta, ef)
    comp = compress(target, kind, topk_ratio)
    decoded = decompress(comp, target)
    new_ef = jax.tree.map(lambda t, d: t - d, target, decoded)
    return decoded, new_ef, compressed_bytes(comp)
