"""The pluggable outer-method layer: ONE registry from kernels to scenarios.

An :class:`OuterMethod` is the single source of truth for everything a
method means across the stack:

  * per-leaf reference correction (``correct`` hook — the math the paper
    states, used by ``apply_arrival`` and the dist outer exchange);
  * packed-path hooks (``packed_coeffs``: which segment stats the fused
    kernel needs + the per-block coefficient triple ``(cu, cv, cq)`` with
    ``g = cu*delta + cv*m + cq*delta^2*m`` — so ``kernels/packed.py``
    never branches on method strings);
  * dropped-arrival decay behaviour (``decay_scale``: the scalar ``s``
    with ``G = s*m`` when the pseudo-gradient is suppressed, generalizing
    the old ``_decay_coeffs``);
  * the outer-update *schedule* (``outer_coeffs``: ``(am, bm, ab, cg,
    cm)`` — ``None`` means the standard Nesterov update of Eqs. 17-19;
    methods with ``buffer_period > 0`` additionally keep a gradient
    accumulator, e.g. delayed-Nesterov);
  * look-ahead-init participation (replacing the hard-coded
    ``method in ("heloco", "mla")`` gate in the synchronizer);
  * Table-3 outer-optimizer defaults and the benchmark-dialect aliases
    ("async-heloco", ...) that the scenario layer and benchmarks resolve
    through :func:`canonical` — no duplicated alias tables.

Adding a method is ~50 lines: define the hooks, ``register(OuterMethod(
...))``, and it automatically rides the packed fast path, the wall-clock
runtime, the scenario registry, and the golden-trace CI gate (see
docs/methods.md for a worked example).

This module is the ONLY place allowed to encode per-method behaviour;
``grep -rn 'method ==' src/ benchmarks/`` must stay empty outside it.

Generalized update (one fused packed sweep, see ``kernels/packed.py``):

    G    = rho * (cu*Delta + cv*m + cq*Delta^2*m)     # corrected, weighted
    acc  = b + G                                       # gradient buffer
    m'   = am*m + bm*acc
    b'   = ab*acc
    p'   = p - eta*(cg*G + ca*acc + cm*m')

``outer_coeffs`` may return 5 coefficients ``(am, bm, ab, cg, cm)`` —
``ca`` defaults to 0 — or all 6; ``ca`` lets buffered-aggregation methods
(FedBuff) step the parameters with the accumulator average at a boundary.
The standard Nesterov schedule is ``(am, bm, ab, cg, cm) = (mu, 1-mu, 0,
1, mu)`` with ``b = 0``, which collapses to Eqs. 17-19 exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig

PyTree = Any


# ---------------------------------------------------------------------------
# Arrival context: everything a hook may read
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArrivalCtx:
    """Per-arrival inputs threaded to every hook. ``rho``/``tau``/``phase``
    may be traced scalars (the synchronizer jits over them)."""
    outer_lr: float
    mu: float
    h: Optional[HeLoCoConfig] = None
    rho: Any = 1.0
    tau: Any = 0.0                   # staleness (fp32 scalar)
    phase: Any = None                # outer-step index at arrival (int32);
    # None means step 0 — only buffered schedules read it
    stacked_axes: Optional[PyTree] = None
    use_kernel: bool = False
    layout: Any = None               # packing.BlockLayout (packed path only)
    interpret: Optional[bool] = None


def _phase(ctx: ArrivalCtx):
    return jnp.asarray(0 if ctx.phase is None else ctx.phase, jnp.int32)


def _tau_norm(ctx: ArrivalCtx, clip: float):
    """min(tau, clip)/clip — the shared staleness normalization (the MLA
    paper constant lives on the method definition, not inline)."""
    return jnp.minimum(jnp.asarray(ctx.tau).astype(jnp.float32), clip) / clip


# ---------------------------------------------------------------------------
# The method definition object
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OuterMethod:
    """Complete definition of one outer method (see module docstring)."""
    name: str
    description: str
    # -- Table-3 outer-optimizer defaults (paper Appendix A.5) --------------
    outer_lr: float
    momentum: float = 0.9
    weight_factor: str = "base"      # "base" sqrt(k)/k | "average" 1/k | "one"
    lookahead_init: bool = False     # Eq. 5 look-ahead participation AND its
    # Table-3 default (methods that can use it default it on)
    # -- identity -----------------------------------------------------------
    aliases: Tuple[str, ...] = ()    # benchmark-dialect names ("async-heloco")
    sync: bool = False               # barrier method: engines run sync rounds
    outer_lr_cap: Optional[float] = None   # launcher clamp (async Nesterov)
    # -- method constants ---------------------------------------------------
    tau_clip: float = 0.0            # staleness normalization clip (0 = n/a)
    dc_lambda: float = 0.0           # delay-compensation strength (dcasgd)
    stale_alpha: float = 0.0         # polynomial staleness exponent
    buffer_period: int = 0           # >0: gradient accumulator, momentum
    # refresh every N arrivals (delayed-Nesterov / FedBuff)
    batchable: bool = True           # False: the server's commit buffer must
    # flush before/after every arrival of this method (ordering constraint)
    # -- hooks --------------------------------------------------------------
    correct: Callable = None         # (m, ctx, delta, momentum) -> g pytree
    packed_coeffs: Callable = None   # (m, ctx, dbuf, mbuf) -> (cu, cv, cq)
    packed_multi_coeffs: Callable = None  # (m, ctxs, dstack, mbuf) ->
    # per-delta ((K,B) cu, (K,B) cv, (K,B) cq | None) for a flush of K
    # coalesced arrivals; None -> the generic per-delta loop (exact for
    # hooks that never read the momentum buffer — every momentum-DEPENDENT
    # hook must supply its own, as heloco does via the Gram recursion)
    decay_scale: Callable = None     # (m, ctx) -> scalar s (G = s*m, delta=0)
    outer_coeffs: Callable = None    # (m, ctx) -> (am, bm, ab, cg, cm[, ca]);
    # None -> the standard Nesterov schedule (byte-identical legacy path)

    def __post_init__(self):
        assert self.weight_factor in ("base", "average", "one"), \
            self.weight_factor
        assert self.correct is not None and self.packed_coeffs is not None, \
            f"method {self.name!r} must define correct + packed_coeffs hooks"
        if self.decay_scale is None:
            object.__setattr__(self, "decay_scale", _zero_decay)

    # ------------------------------------------------------------ structure
    @property
    def uses_buffer(self) -> bool:
        return self.buffer_period > 0

    @property
    def custom_update(self) -> bool:
        """True when the outer update deviates from the standard Nesterov
        schedule (extra state and/or non-default coefficients)."""
        return self.uses_buffer or self.outer_coeffs is not None

    def defaults(self) -> Dict[str, Any]:
        """The Table-3 preset row (the old METHOD_TABLE entry shape)."""
        return dict(outer_lr=self.outer_lr, momentum=self.momentum,
                    weight_factor=self.weight_factor,
                    lookahead_init=self.lookahead_init)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, OuterMethod] = {}
_ALIASES: Dict[str, str] = {}


def register(m: OuterMethod) -> OuterMethod:
    if m.name in _REGISTRY or m.name in _ALIASES:
        raise ValueError(f"duplicate outer method name {m.name!r}")
    for a in m.aliases:
        if a in _ALIASES or a in _REGISTRY:
            raise ValueError(f"duplicate outer method alias {a!r}")
    _REGISTRY[m.name] = m
    for a in m.aliases:
        _ALIASES[a] = m.name
    return m


def get(name: str) -> OuterMethod:
    """Look up a method by canonical name or benchmark-dialect alias."""
    key = _ALIASES.get(name, name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(f"unknown outer method {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))} (aliases: "
                       f"{', '.join(sorted(_ALIASES))})") from None


def resolve(method) -> OuterMethod:
    """Accept an OuterMethod instance or any registered name/alias."""
    if isinstance(method, OuterMethod):
        return method
    return get(method)


def canonical(name: str) -> str:
    return get(name).name


def names() -> List[str]:
    return list(_REGISTRY)


def all_methods() -> List[OuterMethod]:
    return list(_REGISTRY.values())


def cli_names() -> List[str]:
    """Canonical names + aliases (the launcher's --method choices)."""
    return sorted(_REGISTRY) + sorted(_ALIASES)


def method_table() -> Dict[str, Dict[str, Any]]:
    """Table-3 defaults keyed by canonical name — the registry view that
    replaced the hand-maintained METHOD_TABLE dict."""
    return {m.name: m.defaults() for m in _REGISTRY.values()}


def alias_table() -> Dict[str, str]:
    """Benchmark-dialect alias -> canonical name (the registry view that
    replaced METHOD_PRESETS / the benchmarks.common duplicate)."""
    return dict(_ALIASES)


# ---------------------------------------------------------------------------
# Generic update drivers (used by core.heloco for non-standard schedules)
# ---------------------------------------------------------------------------

def standard_coeffs(mu):
    """(am, bm, ab, cg, cm) of the plain Nesterov schedule (Eqs. 17-19)."""
    return mu, 1.0 - mu, 0.0, 1.0, mu


def schedule_coeffs(m: OuterMethod, ctx: ArrivalCtx):
    """The method's 6-tuple ``(am, bm, ab, cg, cm, ca)`` — pads legacy
    5-tuple ``outer_coeffs`` hooks with ``ca = 0``."""
    c = m.outer_coeffs(m, ctx) if m.outer_coeffs else standard_coeffs(ctx.mu)
    return (*c, 0.0) if len(c) == 5 else c


def decay_coeffs(m: OuterMethod, ctx: ArrivalCtx):
    """Scalar coefficients of the dropped-arrival outer step for methods on
    the STANDARD schedule. With the pseudo-gradient suppressed the
    corrected gradient collapses to G = s*m (``decay_scale``), so
      m' = c_m m;  theta' = theta - eta c_p m
    and no zero pytree / O(d) correction sweep is ever needed."""
    g = ctx.rho * m.decay_scale(m, ctx)
    c_m = ctx.mu + (1.0 - ctx.mu) * g
    c_p = g + ctx.mu * c_m
    return c_m, c_p


def scheduled_outer_update(m: OuterMethod, ctx: ArrivalCtx, state, g):
    """Per-leaf generalized outer step (see module docstring) for methods
    whose schedule deviates from plain Nesterov (``custom_update``)."""
    from repro.core.heloco import OuterState
    eta, rho = ctx.outer_lr, ctx.rho
    am, bm, ab, cg, cm, ca = schedule_coeffs(m, ctx)
    aux = state.aux
    if aux is None:
        aux = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                           state.momentum)
    acc = jax.tree.map(lambda b, gi: b + rho * gi.astype(jnp.float32),
                       aux, g)
    momentum = jax.tree.map(lambda mm, a: am * mm + bm * a,
                            state.momentum, acc)
    params = jax.tree.map(
        lambda p, mnew, a, gi: (p.astype(jnp.float32)
                                - eta * (cg * rho * gi.astype(jnp.float32)
                                         + ca * a + cm * mnew)
                                ).astype(p.dtype),
        state.params, momentum, acc, g)
    new_aux = jax.tree.map(lambda a: ab * a, acc)
    return OuterState(params=params, momentum=momentum,
                      step=state.step + 1,
                      aux=new_aux if m.uses_buffer else None)


def scheduled_decay_update(m: OuterMethod, ctx: ArrivalCtx, state):
    """Dropped-arrival step for ``custom_update`` methods: the generalized
    update applied to the collapsed gradient g = s*m (``decay_scale``).
    Unlike the standard-schedule scalar fast path this materialises one
    pytree, but it shares the update math with ``scheduled_outer_update``
    exactly — the decay-collapse identity holds by construction."""
    s = m.decay_scale(m, ctx)
    g = jax.tree.map(lambda mm: s * mm, state.momentum)
    return scheduled_outer_update(m, ctx, state, g)


def multi_packed_coeffs(m: OuterMethod, ctxs, dstack, mbuf):
    """Per-delta coefficient rows for a flush of K coalesced arrivals.

    ctxs: one :class:`ArrivalCtx` per delta, in commit order; dstack:
    (K, R, 128). Returns ``(cu, cv, cq)`` with cu/cv (K, B) and cq either
    ``None`` or (K, B) — the coefficients each application j would have
    seen on the sequential path (i.e. against the momentum as of THAT
    application). The default evaluates ``packed_coeffs`` per delta
    against the flush-time momentum buffer, which is exact precisely when
    the hook never reads ``mbuf``; momentum-dependent hooks override
    (heloco's override reconstructs the evolving-momentum statistics from
    one Gram sweep, keeping the whole flush at <= 2 launches)."""
    if m.packed_multi_coeffs is not None:
        return m.packed_multi_coeffs(m, ctxs, dstack, mbuf)
    outs = [m.packed_coeffs(m, ctx, dstack[j], mbuf)
            for j, ctx in enumerate(ctxs)]
    cu = jnp.stack([o[0] for o in outs])
    cv = jnp.stack([o[1] for o in outs])
    if outs[0][2] is None:
        return cu, cv, None
    return cu, cv, jnp.stack([o[2] for o in outs])


def multi_schedule_coeffs(m: OuterMethod, ctxs):
    """Stack :func:`schedule_coeffs` over a flush: six (K,) vectors
    ``(am, bm, ab, cg, cm, ca)`` — each delta's boundary state toggles its
    own slot of the multi acc kernel's scalar table."""
    rows = [schedule_coeffs(m, ctx) for ctx in ctxs]
    return tuple(jnp.stack([jnp.asarray(r[i], jnp.float32) for r in rows])
                 for i in range(6))


def scheduled_decay_packed(m: OuterMethod, ctx: ArrivalCtx, pbuf, mbuf,
                           abuf=None):
    """Packed dropped-arrival step for ``custom_update`` methods. Pure
    elementwise buffer math (XLA fuses it into one pass)."""
    eta, rho = ctx.outer_lr, ctx.rho
    am, bm, ab, cg, cm, ca = schedule_coeffs(m, ctx)
    s = m.decay_scale(m, ctx)
    if abuf is None:
        abuf = jnp.zeros_like(mbuf)
    g = rho * s * mbuf
    acc = abuf + g
    m_new = am * mbuf + bm * acc
    p_new = pbuf - eta * (cg * g + ca * acc + cm * m_new)
    if m.uses_buffer:
        return p_new, m_new, ab * acc
    return p_new, m_new


# ---------------------------------------------------------------------------
# Hook implementations
# ---------------------------------------------------------------------------

def _zero_decay(m, ctx):
    """Zero delta collapses to G = 0 (heloco / nesterov / dcasgd / DN)."""
    return 0.0


def _identity_correct(m, ctx, delta, momentum):
    """Nesterov-family: the pseudo-gradient is applied as-is."""
    return delta


def _plain_packed_coeffs(m, ctx, dbuf, mbuf):
    n = ctx.layout.n_blocks
    return jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32), None


# -- HeLoCo (paper Alg. 2) ---------------------------------------------------

def _heloco_correct(m, ctx, delta, momentum):
    from repro.core.heloco import block_correct
    return block_correct(delta, momentum, ctx.h,
                         stacked_axes=ctx.stacked_axes,
                         use_kernel=ctx.use_kernel)


def _heloco_packed_coeffs(m, ctx, dbuf, mbuf):
    from repro.kernels import packed as pk
    stats = pk.packed_stats(dbuf, mbuf, jnp.asarray(ctx.layout.row_block),
                            ctx.layout.n_blocks, interpret=ctx.interpret,
                            ranges=ctx.layout.block_row_ranges)
    cu, cv = pk.branch_scalars(stats, ctx.h)
    return cu, cv, None


def _heloco_multi_coeffs(m, ctxs, dstack, mbuf):
    """Evolving-momentum branch statistics for K coalesced deltas from ONE
    Gram sweep. The momentum after j applications stays inside
    span[m0, d_1..d_j], so tracking its basis coordinates ``alpha`` (B,
    K+1) per block turns every (dot, uu, vv) the sequential path would
    measure into an O(B K^2) contraction of the per-block Gram matrix —
    no further O(d) work. fp32-close (not bitwise) to the sequential
    statistics for K > 1; K = 1 flushes take the single-arrival path."""
    from repro.kernels import packed as pk
    layout = ctxs[0].layout
    k = dstack.shape[0]
    gram = pk.packed_multi_gram(mbuf, dstack, layout.block_row_ranges,
                                interpret=ctxs[0].interpret)   # (B, K+1, K+1)
    alpha = jnp.zeros((layout.n_blocks, k + 1), jnp.float32)
    alpha = alpha.at[:, 0].set(1.0)                 # m_cur = 1 * m0
    cus, cvs = [], []
    for j, ctx in enumerate(ctxs):
        e = j + 1                                   # basis slot of d_j
        dot = jnp.sum(alpha * gram[:, e, :], axis=1)
        uu = gram[:, e, e]
        vv = jnp.sum(alpha * jnp.einsum("btu,bu->bt", gram, alpha), axis=1)
        cu, cv = pk.branch_scalars(jnp.stack([dot, uu, vv], axis=1), ctx.h)
        cus.append(cu)
        cvs.append(cv)
        # m' = mu*m + (1-mu)*rho*(cu*d_j + cv*m), in basis coordinates
        rho = jnp.asarray(ctx.rho, jnp.float32)
        alpha = alpha * (ctx.mu + (1.0 - ctx.mu) * rho * cv)[:, None]
        alpha = alpha.at[:, e].add((1.0 - ctx.mu) * rho * cu)
    return jnp.stack(cus), jnp.stack(cvs), None


# -- MLA (momentum look-ahead; Ajanthan et al. 2025) -------------------------

def _mla_correct(m, ctx, delta, momentum):
    from repro.core.heloco import mla_correct
    return mla_correct(delta, momentum, ctx.outer_lr, ctx.mu,
                       jnp.asarray(ctx.tau), tau_clip=m.tau_clip)


def _mla_packed_coeffs(m, ctx, dbuf, mbuf):
    scale = ctx.outer_lr * ctx.mu * _tau_norm(ctx, m.tau_clip)
    n = ctx.layout.n_blocks
    return (jnp.ones((n,), jnp.float32),
            jnp.broadcast_to(scale, (n,)), None)


def _mla_decay_scale(m, ctx):
    """MLA of a zero delta is the nonzero G = eta*mu*tau_norm * m."""
    return ctx.outer_lr * ctx.mu * _tau_norm(ctx, m.tau_clip)


# -- delayed-Nesterov (Liu et al. 2024, Asynchronous Local-SGD) --------------

def _dn_outer_coeffs(m, ctx):
    """Buffer incoming (weighted) pseudo-gradients; every N-th arrival the
    momentum refreshes from the buffer average and the buffer resets:

      non-boundary:  b' = b + G;   m' = m;             p' = p - eta(G + mu m')
      boundary:      b' = 0;       m' = mu m + (1-mu)(b+G)/N;  same p' form
    """
    n = m.buffer_period
    boundary = (((_phase(ctx) + 1) % n) == 0).astype(jnp.float32)
    am = 1.0 - boundary * (1.0 - ctx.mu)
    bm = boundary * ((1.0 - ctx.mu) / n)
    ab = 1.0 - boundary
    return am, bm, ab, 1.0, ctx.mu


# -- FedBuff (Nguyen et al. 2022): K-arrival buffered aggregation ------------

def _fedbuff_outer_coeffs(m, ctx):
    """Buffer incoming (weighted) pseudo-gradients; the server only steps
    at every K-th arrival, applying the buffer AVERAGE through the plain
    Nesterov update, then resets the buffer:

      non-boundary:  b' = b + G;  m' = m;  p' = p
      boundary:      gbar = (b+G)/K;  m' = mu m + (1-mu) gbar;  b' = 0
                     p' = p - eta*(gbar + mu m')

    Between boundaries nothing moves — workers keep training from the
    last aggregate, the FedBuff semantics.
    """
    k = m.buffer_period
    boundary = (((_phase(ctx) + 1) % k) == 0).astype(jnp.float32)
    am = 1.0 - boundary * (1.0 - ctx.mu)
    bm = boundary * ((1.0 - ctx.mu) / k)
    ab = 1.0 - boundary
    cg = 0.0
    cm = boundary * ctx.mu
    ca = boundary / k
    return am, bm, ab, cg, cm, ca


# -- polynomial staleness weighting (Xie et al. 2019 style) ------------------

def _poly_weight(m, ctx):
    tau = jnp.asarray(ctx.tau).astype(jnp.float32)
    return (1.0 + tau) ** (-m.stale_alpha)


def _poly_correct(m, ctx, delta, momentum):
    """Damp the whole pseudo-gradient polynomially in its staleness:
    Delta' = (1 + tau)^-alpha * Delta (tau=0 recovers plain Nesterov)."""
    w = _poly_weight(m, ctx)
    return jax.tree.map(
        lambda d: (w * d.astype(jnp.float32)).astype(d.dtype), delta)


def _poly_packed_coeffs(m, ctx, dbuf, mbuf):
    n = ctx.layout.n_blocks
    return (jnp.broadcast_to(_poly_weight(m, ctx), (n,)),
            jnp.zeros((n,), jnp.float32), None)


# -- DC-ASGD-style delay compensation (Zheng et al. 2017) --------------------

def _dcasgd_correct(m, ctx, delta, momentum):
    """Taylor-style compensation of a stale pseudo-gradient: the server
    drift since dispatch is approximated along the momentum direction,
    theta_t - theta_bak ~ -eta * tau_norm * m, giving

      g~ = Delta + lambda * g^2 * (theta_t - theta_bak)
         = Delta - lambda * eta * tau_norm * (Delta (.) Delta (.) m)
    """
    coef = -(m.dc_lambda * ctx.outer_lr) * _tau_norm(ctx, m.tau_clip)

    def comp(d, mm):
        df = d.astype(jnp.float32)
        return (df + coef * df * df * mm.astype(jnp.float32)).astype(d.dtype)

    return jax.tree.map(comp, delta, momentum)


def _dcasgd_packed_coeffs(m, ctx, dbuf, mbuf):
    n = ctx.layout.n_blocks
    coef = -(m.dc_lambda * ctx.outer_lr) * _tau_norm(ctx, m.tau_clip)
    return (jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32),
            jnp.broadcast_to(coef, (n,)))


# ---------------------------------------------------------------------------
# The registered methods (paper Table 3 + the async Local-SGD baselines)
# ---------------------------------------------------------------------------

register(OuterMethod(
    name="heloco",
    description="Per-tensor-block directional correction of stale "
                "pseudo-gradients + momentum-guided look-ahead (paper "
                "Alg. 1-2).",
    outer_lr=0.7, momentum=0.9, weight_factor="base", lookahead_init=True,
    aliases=("async-heloco",),
    correct=_heloco_correct, packed_coeffs=_heloco_packed_coeffs,
    packed_multi_coeffs=_heloco_multi_coeffs))

register(OuterMethod(
    name="mla",
    description="Momentum Look-Ahead: uniform staleness-proportional "
                "extrapolation along the momentum (Ajanthan et al. 2025).",
    outer_lr=0.7, momentum=0.9, weight_factor="base", lookahead_init=True,
    aliases=("async-mla",), tau_clip=10.0,
    correct=_mla_correct, packed_coeffs=_mla_packed_coeffs,
    decay_scale=_mla_decay_scale))

register(OuterMethod(
    name="nesterov",
    description="Plain asynchronous Nesterov outer optimizer (async "
                "DiLoCo baseline; needs the reduced Table-3 outer LR).",
    outer_lr=0.07, momentum=0.9, weight_factor="base", lookahead_init=False,
    aliases=("async-nesterov",), outer_lr_cap=0.07,
    correct=_identity_correct, packed_coeffs=_plain_packed_coeffs))

register(OuterMethod(
    name="sync_nesterov",
    description="Synchronous DiLoCo/Nesterov barrier baseline: the "
                "slowest worker gates every round.",
    outer_lr=0.7, momentum=0.9, weight_factor="average",
    lookahead_init=False, aliases=("sync-nesterov",), sync=True,
    correct=_identity_correct, packed_coeffs=_plain_packed_coeffs))

register(OuterMethod(
    name="delayed_nesterov",
    description="Delayed Nesterov (Liu et al. 2024): buffer incoming "
                "pseudo-gradients, momentum step every N arrivals.",
    outer_lr=0.7, momentum=0.9, weight_factor="base", lookahead_init=False,
    aliases=("async-delayed-nesterov", "dn"), buffer_period=4,
    correct=_identity_correct, packed_coeffs=_plain_packed_coeffs,
    outer_coeffs=_dn_outer_coeffs))

register(OuterMethod(
    name="fedbuff",
    description="FedBuff-style buffered asynchronous aggregation: the "
                "server averages every K incoming pseudo-gradients into "
                "one outer Nesterov step (Nguyen et al. 2022).",
    outer_lr=0.7, momentum=0.9, weight_factor="one", lookahead_init=False,
    aliases=("async-fedbuff",), buffer_period=4,
    correct=_identity_correct, packed_coeffs=_plain_packed_coeffs,
    outer_coeffs=_fedbuff_outer_coeffs))

register(OuterMethod(
    name="poly_stale",
    description="Polynomial staleness weighting: the pseudo-gradient is "
                "damped by (1+tau)^-alpha before the Nesterov outer step "
                "(staleness-aware async SGD baseline).",
    outer_lr=0.07, momentum=0.9, weight_factor="base", lookahead_init=False,
    aliases=("async-poly-stale",), outer_lr_cap=0.07, stale_alpha=0.5,
    correct=_poly_correct, packed_coeffs=_poly_packed_coeffs))

register(OuterMethod(
    name="dcasgd",
    description="DC-ASGD-style Taylor delay compensation of stale "
                "pseudo-gradients, scaled by staleness tau.",
    outer_lr=0.07, momentum=0.9, weight_factor="base", lookahead_init=False,
    aliases=("async-dcasgd",), outer_lr_cap=0.07, tau_clip=10.0,
    dc_lambda=1.0,
    correct=_dcasgd_correct, packed_coeffs=_dcasgd_packed_coeffs))
