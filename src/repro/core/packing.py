"""Flat packed view of a parameter pytree for the arrival fast path.

The synchronizer's hot loop processes one pseudo-gradient per arrival. At
paper granularity every tensor block needs its own correction scalars, but
nothing about the math requires the blocks to live in separate arrays —
so we flatten the whole pytree ONCE into a single fp32 ``(R, 128)`` buffer
plus a static :class:`BlockLayout`, and every O(d) sweep (correction
statistics, fused correct+outer update, quantization) becomes a single
kernel launch over that buffer instead of one launch per leaf.

Memory format (see also docs/packed_layout.md):

  * Leaves are laid out back-to-back in pytree-flatten order.
  * A leaf with ``n`` stacked leading layer axes (scanned layer stacks) is
    split into ``prod(shape[:n])`` independent blocks — one per layer — so
    packing preserves the paper's per-tensor block granularity.
  * Each block is zero-padded up to a whole number of 128-lane rows and
    starts on a row boundary; ``row_block[r]`` gives the block id owning
    row ``r``. Zero padding is invariant under every packed sweep (stats
    see zero contributions; the fused update maps 0 -> 0), so it is never
    re-zeroed.
  * Trailing filler rows that align R to the kernel row-tile are assigned
    block id 0; they hold zeros and stay zero.

``pack``/``unpack`` are pure jittable functions of the (static) layout;
the buffer dtype is fp32, which doubles as the master copy of bf16 params
(unpack casts back to each leaf's dtype).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tiling import LANES, padded_rows

PyTree = Any


class Packed(NamedTuple):
    """A pytree value that already lives in packed (R, 128) form.

    ``pack`` unwraps it for free, so producers that naturally end with a
    packed buffer (e.g. the packed int8 round-trip) can hand it straight
    to the packed arrival path without an unpack -> re-pack detour. Being
    a NamedTuple it is also a pytree, so tree-wide arithmetic (e.g. the
    sync-round average) maps over the buffer transparently.
    """
    buf: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static placement of one pytree leaf inside the packed buffer."""
    shape: Tuple[int, ...]
    dtype: Any                 # np.dtype of the original leaf
    n_stack: int               # number of blocks (prod of stacked layer axes)
    block_elems: int           # elements per block
    rows_per_block: int        # 128-lane rows per block (zero-padded)
    start_row: int
    start_block: int


@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Static map between a parameter pytree and its packed (R, 128) view.

    Hashable (usable as a jit static argument); the device-independent
    ``row_block`` map is materialised lazily and cached.
    """
    leaves: Tuple[LeafSpec, ...]
    treedef: Any
    data_rows: int             # rows backed by leaf data
    n_rows: int                # R: data_rows aligned to the kernel row tile
    n_blocks: int
    total_elems: int           # real (unpadded) elements

    @functools.cached_property
    def row_block(self) -> np.ndarray:
        """(R,) int32: block id of each row (filler rows -> block 0)."""
        ids = np.zeros(self.n_rows, np.int32)
        for leaf in self.leaves:
            r0 = leaf.start_row
            for s in range(leaf.n_stack):
                ids[r0 + s * leaf.rows_per_block:
                    r0 + (s + 1) * leaf.rows_per_block] = leaf.start_block + s
        return ids

    @functools.cached_property
    def block_sizes(self) -> np.ndarray:
        """(B,) int64: real element count of each block."""
        sizes = np.zeros(self.n_blocks, np.int64)
        for leaf in self.leaves:
            sizes[leaf.start_block:leaf.start_block + leaf.n_stack] = \
                leaf.block_elems
        return sizes

    @functools.cached_property
    def block_row_ranges(self) -> tuple:
        """((start_row, end_row), ...) per block, in block-id order.

        Blocks are CONTIGUOUS row ranges by construction, so per-block
        reductions over per-row partials can be static slices — much
        cheaper than a scatter-based segment sum, and exact.
        """
        ranges = [None] * self.n_blocks
        for leaf in self.leaves:
            for s in range(leaf.n_stack):
                r0 = leaf.start_row + s * leaf.rows_per_block
                ranges[leaf.start_block + s] = (r0, r0 + leaf.rows_per_block)
        return tuple(ranges)


def build_layout(params: PyTree,
                 stacked_axes: Optional[PyTree] = None) -> BlockLayout:
    """Compute the static layout for ``params``.

    stacked_axes: optional pytree of ints (same structure) giving the number
    of leading layer axes per leaf; each layer becomes its own block, same
    as :func:`repro.core.heloco.block_correct`.
    """
    vals, treedef = jax.tree.flatten(params)
    if not vals:
        raise ValueError("cannot build a BlockLayout for an empty pytree")
    if stacked_axes is None:
        axes = [0] * len(vals)
    else:
        axes, axes_def = jax.tree.flatten(stacked_axes)
        if axes_def != treedef:
            raise ValueError("stacked_axes structure does not match params")
    specs = []
    row = block = elems = 0
    for x, nax in zip(vals, axes):
        shape = tuple(int(s) for s in x.shape)
        nax = int(nax)
        if nax > len(shape):
            raise ValueError(f"stacked_axes {nax} exceeds rank of {shape}")
        n_stack = int(np.prod(shape[:nax], dtype=np.int64)) if nax else 1
        block_elems = int(np.prod(shape[nax:], dtype=np.int64))
        rpb = max(1, -(-block_elems // LANES))
        specs.append(LeafSpec(shape=shape, dtype=np.dtype(x.dtype),
                              n_stack=n_stack, block_elems=block_elems,
                              rows_per_block=rpb, start_row=row,
                              start_block=block))
        row += n_stack * rpb
        block += n_stack
        elems += n_stack * block_elems
    n_rows = padded_rows(row * LANES)  # align row count to the kernel tile
    return BlockLayout(leaves=tuple(specs), treedef=treedef, data_rows=row,
                       n_rows=n_rows, n_blocks=block, total_elems=elems)


def pack(layout: BlockLayout, tree: PyTree,
         dtype=jnp.float32) -> jnp.ndarray:
    """Flatten ``tree`` into the packed (R, 128) buffer (jittable).

    A :class:`Packed` value passes through unwrapped (it is already the
    buffer for this layout).
    """
    if isinstance(tree, Packed):
        return tree.buf.astype(dtype)
    vals = jax.tree.leaves(tree)
    if len(vals) != len(layout.leaves):
        raise ValueError("tree does not match layout")
    parts = []
    for x, leaf in zip(vals, layout.leaves):
        xf = jnp.asarray(x).astype(dtype).reshape(leaf.n_stack,
                                                  leaf.block_elems)
        pad = leaf.rows_per_block * LANES - leaf.block_elems
        if pad:
            xf = jnp.pad(xf, ((0, 0), (0, pad)))
        parts.append(xf.reshape(leaf.n_stack * leaf.rows_per_block, LANES))
    buf = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    filler = layout.n_rows - layout.data_rows
    if filler:
        buf = jnp.pad(buf, ((0, filler), (0, 0)))
    return buf


def unpack(layout: BlockLayout, buf: jnp.ndarray,
           dtype=None) -> PyTree:
    """Rebuild the pytree from a packed buffer (jittable).

    dtype: override the per-leaf output dtype (e.g. fp32 for momentum);
    default restores each leaf's original dtype.
    """
    leaves_out = []
    for leaf in layout.leaves:
        rows = leaf.n_stack * leaf.rows_per_block
        x = buf[leaf.start_row:leaf.start_row + rows]
        x = x.reshape(leaf.n_stack, leaf.rows_per_block * LANES)
        x = x[:, :leaf.block_elems].reshape(leaf.shape)
        leaves_out.append(x.astype(dtype or leaf.dtype))
    return jax.tree.unflatten(layout.treedef, leaves_out)


def zeros(layout: BlockLayout, dtype=jnp.float32) -> jnp.ndarray:
    """A packed buffer of zeros (e.g. fresh momentum / error feedback)."""
    return jnp.zeros((layout.n_rows, LANES), dtype)
