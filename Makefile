# Developer entry points. `make verify` is the tier-1 gate: the fast test
# suite on CPU with interpret-mode Pallas kernels (auto-selected on CPU),
# so kernel regressions are caught without a TPU. Long-running lanes are
# marker-split (pytest.ini): `slow` and `wallclock` tests plus the
# golden-trace scenario gates run in the CI matrix (`make scenarios`,
# `make bench-check`).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify verify-ci test test-slow test-wallclock bench bench-full \
	bench-runtime bench-check bench-check-arrival bench-check-runtime \
	bench-report smoke-wallclock scenarios scenarios-sim \
	scenarios-wallclock record-goldens sweep-smoke chaos console-smoke

verify:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -x -q

# CI variant: no -x (a red run reports ALL failures) + junit artifact
verify-ci:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q --junitxml=results/junit/tier1.xml

test: verify

# the marker-split lanes CI runs in the scenarios-* jobs
test-slow:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q -m "slow and not wallclock" \
		--junitxml=results/junit/slow.xml

test-wallclock:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q -m wallclock \
		--junitxml=results/junit/wallclock.xml

# micro-benchmarks only; persists arrival-path rows to
# results/bench/BENCH_arrival.json
bench:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --skip-training

bench-full:
	$(PYTHON) -m benchmarks.run --full

# simulator vs threaded concurrent runtime (deterministic + free-running);
# persists arrivals/sec, server occupancy, queue depth, overlap evidence
# to results/bench/BENCH_runtime.json
bench-runtime:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --runtime

# regression gate: fresh bench rows vs committed benchmarks/baselines/
# (per-metric tolerance bands; exact for launch-count/HBM contracts).
# BENCH_SLACK widens the timing band on slow/noisy hosts (CI sets 25).
# CI splits the families across lanes: tier1 gates the arrival path,
# scenarios-wallclock gates the runtime benches it runs anyway.
BENCH_SLACK ?= 4.0
bench-check: bench bench-runtime
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.check_regression \
		--timing-slack $(BENCH_SLACK)

bench-check-arrival: bench
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.check_regression \
		--which arrival --timing-slack $(BENCH_SLACK)

bench-check-runtime: bench-runtime
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.check_regression \
		--which runtime --timing-slack $(BENCH_SLACK)

# markdown trajectory of the accumulated bench histories
# -> results/bench/BENCH_REPORT.md
bench-report:
	$(PYTHON) -m benchmarks.report

# CI-sized budgeted ablation grid (2 methods x 2 scenarios x fixed-token
# + fixed-wallclock budgets): comparison tables + staleness->alignment
# artifact from real telemetry streams -> results/sweeps/smoke/
sweep-smoke:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.sweeps run smoke --force

# golden-trace gates: verify every registered scenario against
# results/golden/ (sim fp32-exact, deterministic wallclock trace-identical,
# free-running tolerance-banded). This is what the CI matrix gates on.
scenarios:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all

scenarios-sim:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all \
		--engine-filter sim

scenarios-wallclock:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all \
		--engine-filter wallclock
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all \
		--engine-filter sim --cross-only

# unreliable-delivery gate (docs/faults.md): the chaos golden traces —
# chaos_lossy / chaos_corrupt must reproduce wallclock_hetero's exact
# param digest through drop/dup/reorder/corruption, chaos_partition must
# survive a black-holed worker via liveness recovery — plus a short
# free-running lossy training smoke through the --chaos launcher preset.
chaos:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify \
		chaos_lossy chaos_corrupt chaos_partition
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 --chaos \
		--paces 1,1,2,6 --workers 4 --outer 6 --inner 1 \
		--batch 2 --seq 16 --eval-every 6

# (re)generate the committed golden traces after an intentional change
record-goldens:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run record --all

# observability smoke (docs/observability.md): a free-running chaos run
# streams telemetry live to disk while exporting trace spans and a
# stats-summary JSON; then the operator console renders a headless
# snapshot of the stream and the trace is validated as well-formed
# Chrome trace-event JSON (Perfetto-loadable).
console-smoke:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 --chaos \
		--paces 1,1,2,6 --workers 4 --outer 6 --inner 1 \
		--batch 2 --seq 16 --eval-every 3 \
		--telemetry results/obs/console_smoke.jsonl --telemetry-every 1 \
		--trace results/obs/console_smoke.trace.json \
		--stats-json results/obs/console_smoke.stats.json
	$(PYTHON) -m repro.obs console results/obs/console_smoke.jsonl --once
	$(PYTHON) -m repro.obs trace --validate \
		results/obs/console_smoke.trace.json

# tiny end-to-end wallclock-engine training run (CI smoke)
smoke-wallclock:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 \
		--paces 1,1,2,6 --workers 4 --outer 8 --inner 2 \
		--batch 2 --seq 16 --eval-every 8
