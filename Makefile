# Developer entry points. `make verify` is the tier-1 gate: the full test
# suite on CPU with interpret-mode Pallas kernels (auto-selected on CPU),
# so kernel regressions are caught without a TPU.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify test bench bench-full bench-runtime smoke-wallclock

verify:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -x -q

test: verify

# micro-benchmarks only; persists arrival-path rows to BENCH_arrival.json
bench:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --skip-training

bench-full:
	$(PYTHON) -m benchmarks.run --full

# simulator vs threaded concurrent runtime (deterministic + free-running);
# persists arrivals/sec, server occupancy, queue depth, overlap evidence
# to BENCH_runtime.json
bench-runtime:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --runtime

# tiny end-to-end wallclock-engine training run (the CI smoke job)
smoke-wallclock:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 \
		--paces 1,1,2,6 --workers 4 --outer 8 --inner 2 \
		--batch 2 --seq 16 --eval-every 8
