# Developer entry points. `make verify` is the tier-1 gate: the fast test
# suite on CPU with interpret-mode Pallas kernels (auto-selected on CPU),
# so kernel regressions are caught without a TPU. Long-running lanes are
# marker-split (pytest.ini): `slow` and `wallclock` tests plus the
# golden-trace scenario gates run in the CI matrix (`make scenarios`,
# `make bench-check`).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify verify-ci test test-slow test-wallclock test-proc bench \
	bench-full bench-runtime bench-scale bench-check bench-check-arrival \
	bench-check-runtime bench-check-scale bench-report smoke-wallclock \
	smoke-proc scenarios scenarios-sim scenarios-wallclock scenarios-proc \
	record-goldens sweep-smoke chaos console-smoke obs-smoke

verify:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -x -q

# CI variant: no -x (a red run reports ALL failures) + junit artifact
verify-ci:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q --junitxml=results/junit/tier1.xml

test: verify

# the marker-split lanes CI runs in the scenarios-* jobs
test-slow:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q -m "slow and not wallclock" \
		--junitxml=results/junit/slow.xml

test-wallclock:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q -m wallclock \
		--junitxml=results/junit/wallclock.xml

# multi-process socket-transport lane. PROC_FLAGS probes for the CI-only
# plugins (requirements-ci.txt): pytest-timeout turns a wedged rendezvous
# into a single failed test with thread stacks, pytest-rerunfailures
# grants flaky proc tests exactly one rerun (flake telemetry lands in the
# junit artifact). Locally without the plugins the conftest.py fallback
# watchdog still bounds each test.
PROC_FLAGS := $(shell $(PYTHON) -c "import pytest_timeout, pytest_rerunfailures; print('--timeout=180 --timeout-method=thread --reruns 1')" 2>/dev/null)
test-proc:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q -m proc $(PROC_FLAGS) \
		--junitxml=results/junit/proc.xml

# micro-benchmarks only; persists arrival-path rows to
# results/bench/BENCH_arrival.json
bench:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --skip-training

bench-full:
	$(PYTHON) -m benchmarks.run --full

# simulator vs threaded concurrent runtime (deterministic + free-running);
# persists arrivals/sec, server occupancy, queue depth, overlap evidence
# to results/bench/BENCH_runtime.json
bench-runtime:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --runtime

# batched-arrival scale benchmark (docs/scale.md): per-method launch
# contract for a K-arrival flush, amortized bookkeeping us/arrival at
# N in {64, 1k, 10k}, and the no-implicit-h2d transfer probe; persists
# to results/bench/BENCH_scale.json
bench-scale:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --scale

# regression gate: fresh bench rows vs committed benchmarks/baselines/
# (per-metric tolerance bands; exact for launch-count/HBM contracts).
# BENCH_SLACK widens the timing band on slow/noisy hosts (CI sets 25).
# CI splits the families across lanes: tier1 gates the arrival path,
# scenarios-wallclock gates the runtime benches it runs anyway.
BENCH_SLACK ?= 4.0
bench-check: bench bench-runtime bench-scale
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.check_regression \
		--timing-slack $(BENCH_SLACK)

bench-check-arrival: bench
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.check_regression \
		--which arrival --timing-slack $(BENCH_SLACK)

bench-check-runtime: bench-runtime
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.check_regression \
		--which runtime --timing-slack $(BENCH_SLACK)

bench-check-scale: bench-scale
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.check_regression \
		--which scale --timing-slack $(BENCH_SLACK)

# markdown trajectory of the accumulated bench histories
# -> results/bench/BENCH_REPORT.md
bench-report:
	$(PYTHON) -m benchmarks.report

# CI-sized budgeted ablation grid (2 methods x 2 scenarios x fixed-token
# + fixed-wallclock budgets): comparison tables + staleness->alignment
# artifact from real telemetry streams -> results/sweeps/smoke/
sweep-smoke:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.sweeps run smoke --force

# golden-trace gates: verify every registered scenario against
# results/golden/ (sim fp32-exact, deterministic wallclock trace-identical,
# free-running tolerance-banded). This is what the CI matrix gates on.
scenarios:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all

scenarios-sim:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all \
		--engine-filter sim

scenarios-wallclock:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all \
		--engine-filter wallclock --transport-filter inproc
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all \
		--engine-filter sim --cross-only

# cross-process gate (docs/runtime.md, "Process transport"): the
# socket-registered scenarios verify against their goldens; then a slice
# of the wallclock/chaos grid and the sim cross-replays are re-run over
# real worker processes against the UNMODIFIED committed goldens — the
# process boundary must not change a single trace. Plus the proc test
# lane and a 2-process free-running training smoke.
scenarios-proc:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify --all \
		--transport-filter socket
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify \
		socket_hetero --obs
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify \
		wallclock_hetero chaos_lossy chaos_corrupt --transport socket
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify \
		paper_hetero_severe drop_stale int8_dylu gossip_ring \
		--cross-only --transport socket
	$(MAKE) test-proc
	$(MAKE) smoke-proc
	$(MAKE) obs-smoke

# unreliable-delivery gate (docs/faults.md): the chaos golden traces —
# chaos_lossy / chaos_corrupt must reproduce wallclock_hetero's exact
# param digest through drop/dup/reorder/corruption, chaos_partition must
# survive a black-holed worker via liveness recovery — plus a short
# free-running lossy training smoke through the --chaos launcher preset.
# TRANSPORT=socket runs the identical gate over real worker processes
# (child-side fault injection, same dice) against the same goldens.
TRANSPORT ?= inproc
chaos:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run verify \
		chaos_lossy chaos_corrupt chaos_partition \
		$(if $(filter socket,$(TRANSPORT)),--transport socket)
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 --chaos \
		--transport $(TRANSPORT) \
		--paces 1,1,2,6 --workers 4 --outer 6 --inner 1 \
		--batch 2 --seq 16 --eval-every 6

# (re)generate the committed golden traces after an intentional change.
# Guard: refuses while tier-1 is red — re-recording goldens on top of a
# broken tree bakes the breakage into the reference artifacts.
record-goldens:
	@echo "record-goldens: checking tier-1 is green first..."
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -q || \
		{ echo "record-goldens: REFUSED — tier-1 is red; fix the suite \
before re-recording reference traces" >&2; exit 1; }
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.scenarios.run record --all

# observability smoke (docs/observability.md): a free-running chaos run
# streams telemetry live to disk while exporting trace spans and a
# stats-summary JSON; then the operator console renders a headless
# snapshot of the stream and the trace is validated as well-formed
# Chrome trace-event JSON (Perfetto-loadable).
console-smoke:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 --chaos \
		--paces 1,1,2,6 --workers 4 --outer 6 --inner 1 \
		--batch 2 --seq 16 --eval-every 3 \
		--telemetry results/obs/console_smoke.jsonl --telemetry-every 1 \
		--trace results/obs/console_smoke.trace.json \
		--stats-json results/obs/console_smoke.stats.json
	$(PYTHON) -m repro.obs console results/obs/console_smoke.jsonl --once
	$(PYTHON) -m repro.obs trace --validate \
		results/obs/console_smoke.trace.json

# cross-process observability smoke (docs/observability.md,
# "Cross-process collection"): a free-running socket-transport chaos
# train streams v4 telemetry live to disk — child transport records
# riding the obs control channel, commit-buffer flush events from the
# coalescing server — while child spans merge into ONE Chrome trace;
# then the merged trace is gated with --validate, the operator console
# renders a headless snapshot, and the web dashboard's --snapshot
# aggregation is asserted to carry the arrival-rate, staleness,
# transport, and flush panels non-empty. Runs in the scenarios-proc CI
# lane (the observability twin of smoke-proc).
obs-smoke:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 --chaos \
		--transport socket --commit-batch 4 \
		--paces 1,1,2,6 --workers 4 --outer 8 --inner 1 \
		--batch 2 --seq 16 --eval-every 4 \
		--telemetry results/obs/obs_smoke.jsonl --telemetry-every 1 \
		--trace results/obs/obs_smoke.trace.json \
		--stats-json results/obs/obs_smoke.stats.json
	$(PYTHON) -m repro.obs trace --validate results/obs/obs_smoke.trace.json
	$(PYTHON) -m repro.obs console results/obs/obs_smoke.jsonl --once
	$(PYTHON) -m repro.obs web results/obs/obs_smoke.jsonl --snapshot \
		> results/obs/obs_smoke.snapshot.json
	$(PYTHON) -c "import json; p = json.load(open( \
		'results/obs/obs_smoke.snapshot.json')); \
		missing = [k for k in ('arrivals', 'staleness', 'transport', \
		'flush') if not p[k]]; \
		assert not missing, 'empty obs panels: %s' % missing; \
		assert p['arrivals']['rate_per_sec'] > 0, 'zero arrival rate'; \
		assert len(p['transport']['workers']) >= 2, p['transport']; \
		print('obs-smoke: snapshot OK --', p['arrivals']['commits'], \
		'commits,', len(p['transport']['workers']), 'worker procs,', \
		p['flush']['flushes'], 'flushes')"

# tiny end-to-end wallclock-engine training run (CI smoke)
smoke-wallclock:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 \
		--paces 1,1,2,6 --workers 4 --outer 8 --inner 2 \
		--batch 2 --seq 16 --eval-every 8

# free-running end-to-end smoke over REAL worker processes: 2 spawned
# children, socket rendezvous, true arrival order
smoke-proc:
	JAX_PLATFORMS=cpu $(PYTHON) -m repro.launch.train --arch tinygpt-15m \
		--smoke --engine wallclock --free --pace-scale 0.02 \
		--transport socket \
		--paces 1,2 --workers 2 --outer 6 --inner 1 \
		--batch 2 --seq 16 --eval-every 6
