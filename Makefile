# Developer entry points. `make verify` is the tier-1 gate: the full test
# suite on CPU with interpret-mode Pallas kernels (auto-selected on CPU),
# so kernel regressions are caught without a TPU.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify test bench bench-full

verify:
	JAX_PLATFORMS=cpu $(PYTHON) -m pytest -x -q

test: verify

# micro-benchmarks only; persists arrival-path rows to BENCH_arrival.json
bench:
	JAX_PLATFORMS=cpu $(PYTHON) -m benchmarks.run --skip-training

bench-full:
	$(PYTHON) -m benchmarks.run --full
